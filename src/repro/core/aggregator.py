"""Coded gradient aggregation on a JAX SPMD mesh.

Three implementations of the same math, used at different layers:

1. ``protocol_reference`` — the paper's protocol verbatim in pure jnp: every
   worker materializes its coded gradient g̃_w = Σ_j B[w,j]·g_j (the tensor a
   real deployment puts on the wire), the master decodes g = Σ_w a_w·g̃_w.
   Oracle for tests and the convergence benchmarks.  O(m·n) backward passes.

2. ``fused_coded_value_and_grad`` — the production path.  Linear encoding
   commutes with ∇, so worker w's coded gradient is ∇_θ Σ_j B[w,j]·L(D_j),
   ONE backward pass over a weighted loss; folding the decode coefficient
   a_w in as well, the ordinary data-parallel gradient psum that XLA inserts
   *is* the decode:  g = ∇_θ Σ_w a_w Σ_j B[w,j] L(D_j).   Coded DP training
   becomes example-weighted DP — fully pjit/GSPMD-compatible, multi-pod
   safe, zero extra collectives vs naive DP.  (Beyond-paper optimization;
   agreement with (1) is property-tested.)

3. ``faithful_spmd_step`` — the protocol under ``jax.shard_map``: manual over
   the coding axes, auto over 'model' (TP).  Each worker flattens its
   per-slot gradients into one (D,) buffer (``ravel_pytree``), encodes them
   in a single pass through the roofline-optimal ``coded_reduce`` Pallas
   kernel (``interpret=True`` off-TPU), optionally compresses the flat wire
   tensor (int8 + error feedback) exactly where the wire format would apply,
   then decodes with ONE scaled psum over the flat buffer — not a per-leaf
   tree walk.  The master-side unravel back to the param pytree happens once,
   outside the collective.  Used for protocol benchmarks and as the
   compression-enabled path.

The device-resident data-path contract (DESIGN.md §6) lives here too:
``slot_weights_device`` / ``pack_flat_device`` are the in-jit twins of the
host ``slot_weights`` / ``_flat_batch`` pack, consuming the small per-step
device inputs (decode vector ``a`` (m,), ``support`` (m,k)) plus the
plan tensors that the engine keeps device-resident between rebalances.

Deployment note (see DESIGN.md §3): within one SPMD program all chips step in
lock-step, so the (s+1)× compute redundancy buys gradient *exactness when
any ≤s coded workers' contributions are masked out* (deadline-based
exclusion, pod preemption, link loss).  The wall-clock win appears when the
coding axis crosses an MPMD boundary — pods over DCN — which is exactly how
``coding_axes=("pod",)`` configures it; the timing model lives in
core/simulator.py.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.flatten_util import ravel_pytree

from repro.core.coding import CodingScheme
from repro.core.decoding import Decoder
from repro.kernels import ref as kref
from repro.kernels.coded_reduce import coded_reduce_pallas
from repro.kernels.wire import coded_decode_int8_pallas, coded_encode_int8_pallas

__all__ = [
    "CodedPlan",
    "make_plan",
    "slot_weights",
    "slot_weights_device",
    "support_slot_mask",
    "support_slot_mask_device",
    "pack_coded_batch",
    "pack_flat_device",
    "protocol_reference",
    "fused_coded_value_and_grad",
    "faithful_spmd_step",
    "remap_err_rows",
]

PyTree = Any
LossFn = Callable[[PyTree, PyTree], jnp.ndarray]  # (params, slot_batch) -> scalar


def _shard_map(fn, mesh, in_specs, out_specs, manual_axes: tuple[str, ...]):
    """shard_map across jax versions: manual over ``manual_axes``, auto over
    the rest ('model' stays GSPMD-handled either way)."""
    if hasattr(jax, "shard_map"):  # jax >= 0.6
        return jax.shard_map(
            fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            axis_names=frozenset(manual_axes), check_vma=False,
        )
    from jax.experimental.shard_map import shard_map  # jax 0.4.x

    # the `auto=` subgroup path trips an XLA CHECK on 0.4.x CPU, so go fully
    # manual: non-coding axes see replicated blocks (duplicate compute over
    # 'model' — acceptable for the protocol/benchmark path on old jax)
    return shard_map(fn, mesh, in_specs=in_specs, out_specs=out_specs, check_rep=False)


def remap_err_rows(err: jnp.ndarray, old_of_new) -> jnp.ndarray:
    """Per-worker wire-state row remap for a membership transition
    (DESIGN.md §13).

    ``err`` is the spmd backend's (m_old, width) error-feedback buffer;
    ``old_of_new[i]`` is the old index that became new worker ``i``, or
    None for a joiner.  Retained workers keep their accumulated residual
    row — gathered ON DEVICE, so the old buffer is consumed without a host
    round-trip — while joiners (and the rows of departed workers) start
    from zero.  Departed state must not leak: a leaver's residual encodes
    coefficients that no longer exist in the remapped B."""
    err = jnp.asarray(err)
    m_old = int(err.shape[0])
    idx = np.array([m_old if o is None else int(o) for o in old_of_new], np.int32)
    if np.any((idx < 0) | (idx > m_old)):
        raise ValueError(f"row map {list(old_of_new)} out of range for m_old={m_old}")
    padded = jnp.concatenate([err, jnp.zeros((1,) + err.shape[1:], err.dtype)], axis=0)
    return jnp.take(padded, jnp.asarray(idx), axis=0)


@dataclasses.dataclass(frozen=True)
class CodedPlan:
    """Device-feedable view of a CodingScheme.

    Attributes:
      slot_pids: (m, n_max) int32 partition id per worker slot (0-padded).
      slot_mask: (m, n_max) float, 1 for real slots, 0 for padding.
      slot_coeff: (m, n_max) float32, B[w, slot_pids[w, s]] (0 on padding).
      m, k, n_max: sizes.
    """

    slot_pids: np.ndarray
    slot_mask: np.ndarray
    slot_coeff: np.ndarray
    m: int
    k: int
    n_max: int


def make_plan(scheme: CodingScheme, n_slots: int | None = None) -> CodedPlan:
    """``n_slots`` pads every worker to a fixed slot count so elastic
    re-encodes (new c estimates -> new allocation) never change array shapes
    and therefore never trigger recompilation."""
    m, k = scheme.m, scheme.k
    n_max = max(1, max(scheme.allocation.counts))
    if n_slots is not None:
        if n_slots < n_max:
            raise ValueError(f"n_slots={n_slots} < allocation max {n_max}")
        n_max = n_slots
    pids = np.zeros((m, n_max), dtype=np.int32)
    mask = np.zeros((m, n_max), dtype=np.float32)
    coeff = np.zeros((m, n_max), dtype=np.float32)
    for w, parts in enumerate(scheme.allocation.partitions):
        for slot, j in enumerate(parts):
            pids[w, slot] = j
            mask[w, slot] = 1.0
            coeff[w, slot] = scheme.B[w, j]
    return CodedPlan(slot_pids=pids, slot_mask=mask, slot_coeff=coeff, m=m, k=k, n_max=n_max)


def support_slot_mask(plan: CodedPlan, support: np.ndarray) -> np.ndarray:
    """Slot-space view of an (m, k) partial-work completion mask: 1 where
    the worker finished that slot's partition, re-masked by ``slot_mask``
    because padding slots gather pid 0.  The single place the padding
    invariant is encoded — used by the fused weights AND the spmd coeffs."""
    done = np.asarray(support, np.float32)[np.arange(plan.m)[:, None], plan.slot_pids]
    return done * plan.slot_mask


def slot_weights(
    plan: CodedPlan, decode_vec: np.ndarray, support: np.ndarray | None = None
) -> np.ndarray:
    """Fused-path weights: W[w,s] = a_w · B[w, pid(w,s)] / k  (0 on padding).

    Σ_{w,s} W[w,s]·L_{pid(w,s)} = (1/k)·Σ_j (a·B)_j·L_j = mean partition loss,
    so its gradient is the decoded mean gradient.

    ``support`` is the optional (m, k) partial-work completion mask (see
    :class:`~repro.core.decoding.DecodeOutcome`): slots whose partition a
    worker did not finish get weight 0, so the fused/spmd paths differentiate
    exactly the work that exists — the inexact-decode contract.
    """
    a = np.asarray(decode_vec, dtype=np.float32).reshape(plan.m, 1)
    w = a * plan.slot_coeff * plan.slot_mask / plan.k
    if support is not None:
        w = w * support_slot_mask(plan, support)
    return w.astype(np.float32)


def uniform_weights(plan: CodedPlan) -> np.ndarray:
    """Uncoded-DP weights (naive scheme): every real slot weight 1/k."""
    return (plan.slot_mask / plan.k).astype(np.float32)


# ---------------------------------------------------------------------------
# device-resident twins of the host pack/weights (run INSIDE the jitted step)
# ---------------------------------------------------------------------------


def support_slot_mask_device(
    support: jnp.ndarray, slot_pids: jnp.ndarray, slot_mask: jnp.ndarray
) -> jnp.ndarray:
    """In-jit :func:`support_slot_mask`: gather the (m, k) completion mask
    into slot space, re-masked by ``slot_mask`` because padding slots gather
    pid 0 — the device-side home of the padding invariant (used by the fused
    weights AND the spmd wire coefficients)."""
    done = jnp.take_along_axis(support.astype(jnp.float32), slot_pids, axis=1)
    return done * slot_mask


def slot_weights_device(
    a: jnp.ndarray,
    support: jnp.ndarray,
    slot_coeff: jnp.ndarray,
    slot_mask: jnp.ndarray,
    slot_pids: jnp.ndarray,
    k: int,
) -> jnp.ndarray:
    """In-jit :func:`slot_weights`: W[w,s] = a_w·B[w,pid]·done[w,pid]/k.

    ``a`` (m,) and ``support`` (m, k) are the only per-step device inputs;
    ``slot_coeff`` / ``slot_mask`` / ``slot_pids`` are the plan tensors the
    engine keeps device-resident between rebalances.  Callers without
    partial work pass an all-ones ``support`` — `done·mask == mask` then,
    so the exact path is bit-identical to the host formula.
    """
    done = support_slot_mask_device(support, slot_pids, slot_mask)
    w = a.astype(jnp.float32)[:, None] * slot_coeff * done / k
    return w.astype(jnp.float32)


def pack_flat_device(
    partition_batch: dict, slot_pids: jnp.ndarray, weights: jnp.ndarray
) -> dict:
    """In-jit slot pack: partition-major leaves (k, mb, ...) -> the fused
    flat coded batch (m·n_slots·mb, ...) with per-sequence loss weights.

    The (s+1)×-replicated coded working set is materialized HERE, on device,
    by an XLA gather — the host only ever ships the k·mb unique sequences
    (DESIGN.md §6).  ``weights`` is the (m, n_slots) output of
    :func:`slot_weights_device`.
    """
    idx = slot_pids.reshape(-1)  # (m*n_slots,)
    out = {}
    mb = None
    for key, x in partition_batch.items():
        # gather on a 2-D (k, mb·rest) view — XLA lowers row gathers of flat
        # rows to straight memcpys, several× faster than an N-D take
        g = jnp.take(x.reshape((x.shape[0], -1)), idx, axis=0)
        mb = x.shape[1]
        out[key] = g.reshape((-1,) + x.shape[2:])
    out["weight"] = (jnp.repeat(weights.reshape(-1), mb) / mb).astype(jnp.float32)
    return out


def pack_coded_batch(
    partition_batch: PyTree, plan: CodedPlan, idx: jnp.ndarray | None = None
) -> PyTree:
    """Gather partition-major data (k, mb, ...) into slot-major (m, n_max, mb, ...).

    Replication factor is s+1 by construction — this materializes the coded
    working set, which is inherent to gradient coding.  Pass ``idx`` (the
    flattened (m·n_max,) slot_pids as a device array) to reuse a cached
    device copy instead of re-uploading the plan's; the gather runs on a
    2-D (k, mb·rest) view, which XLA lowers to straight row memcpys.
    """
    if idx is None:
        idx = jnp.asarray(plan.slot_pids.reshape(-1))  # (m*n_max,)

    def gather(x):
        out = jnp.take(x.reshape((x.shape[0], -1)), idx, axis=0)
        return out.reshape((plan.m, plan.n_max) + x.shape[1:])

    return jax.tree.map(gather, partition_batch)


# ---------------------------------------------------------------------------
# 1. protocol oracle (paper-verbatim)
# ---------------------------------------------------------------------------


def protocol_reference(
    loss_fn: LossFn,
    params: PyTree,
    partition_batch: PyTree,
    scheme: CodingScheme,
    available: Sequence[int] | None = None,
    decode_vec: np.ndarray | None = None,
    support: np.ndarray | None = None,
    grad_fn: Callable | None = None,
) -> tuple[PyTree, list[PyTree]]:
    """Paper protocol, literally.  Returns (decoded mean gradient, [g̃_w]).

    Workers compute per-partition gradients, encode with their B row, the
    master decodes from the available set.  Not jitted end-to-end (python
    loops) — this is the oracle, not the fast path.  Pass ``decode_vec`` to
    reuse a decode solved elsewhere (e.g. a GradientCode's fast path) and
    ``support`` (m, k completion mask) for partial-work iterations: worker w
    encodes only the partitions it finished, g̃_w = Σ_j B[w,j]·mask[w,j]·g_j.
    ``grad_fn`` lets long-lived callers (StepEngine) pass in a jitted
    ``jax.grad(loss_fn)`` built once, instead of re-tracing it per call.
    """
    m, k = scheme.m, scheme.k
    if grad_fn is None:
        grad_fn = jax.jit(jax.grad(loss_fn))
    part_grads = [
        grad_fn(params, jax.tree.map(lambda x, j=j: x[j], partition_batch)) for j in range(k)
    ]
    coded = []
    for w in range(m):
        gw = jax.tree.map(jnp.zeros_like, params)
        for j in scheme.allocation.partitions[w]:
            bwj = float(scheme.B[w, j]) * (1.0 if support is None else float(support[w, j]))
            gw = jax.tree.map(lambda acc, g, b=bwj: acc + b * g, gw, part_grads[j])
        coded.append(gw)
    if decode_vec is not None:
        a = np.asarray(decode_vec, np.float64)
        avail = [i for i in range(m) if abs(a[i]) > 1e-12]
    else:
        avail = list(range(m)) if available is None else list(available)
        a = Decoder(scheme).decode_vector(avail)
    decoded = jax.tree.map(jnp.zeros_like, params)
    for w in avail:
        if abs(a[w]) < 1e-12:
            continue
        decoded = jax.tree.map(lambda acc, g, aw=float(a[w]): acc + aw * g, decoded, coded[w])
    decoded = jax.tree.map(lambda g: g / k, decoded)
    return decoded, coded


# ---------------------------------------------------------------------------
# 2. fused production path (pjit-native)
# ---------------------------------------------------------------------------


def fused_coded_value_and_grad(loss_fn: LossFn) -> Callable[[PyTree, PyTree, jnp.ndarray], tuple]:
    """Returns f(params, slot_batch, weights) -> (weighted_loss, grads).

    slot_batch leaves: (m, n_max, mb, ...); weights: (m, n_max) from
    ``slot_weights``.  Shard slot axis 0 over the coding axes and XLA's DP
    gradient reduction performs the decode.
    """

    def weighted_loss(params: PyTree, slot_batch: PyTree, weights: jnp.ndarray) -> jnp.ndarray:
        flat = jax.tree.map(lambda x: x.reshape((-1,) + x.shape[2:]), slot_batch)
        losses = jax.vmap(loss_fn, in_axes=(None, 0))(params, flat)  # (m*n_max,)
        return jnp.sum(losses * weights.reshape(-1).astype(losses.dtype))

    return jax.value_and_grad(weighted_loss)


# ---------------------------------------------------------------------------
# 3. faithful SPMD protocol (shard_map, manual over coding axes)
# ---------------------------------------------------------------------------


# wire-format definition lives with the kernel oracles; these aliases keep
# the historical names used throughout this module and its tests
_quantize_int8 = kref.quantize_int8
_dequantize = kref.dequantize


def faithful_spmd_step(
    loss_fn: LossFn,
    mesh: jax.sharding.Mesh,
    coding_axes: tuple[str, ...] = ("data",),
    compress: bool = False,
    interpret: bool | None = None,
    wire_kernel: bool | None = None,
) -> Callable:
    """Paper protocol under shard_map: flat Pallas encode, one-psum decode.

    The returned function f(params, slot_batch, coeff, a, err) ->
    (flat_grads, err') expects leaves of slot_batch shaped (m, n_max, mb, ...)
    sharded over the coding axes on dim 0; coeff = effective B coefficients
    (m, n_max) (slot mask — and any partial-work support mask — already folded
    in); a = decode vector scaled by 1/k, shape (m,); err = per-worker flat
    error-feedback buffer (m, D) when ``compress`` else (m, 1) (each coded
    worker keeps its own quantization residual on the wire tensor).

    Data path per worker: the per-slot gradient pytrees are flattened into a
    (n_max, D) stack (``ravel_pytree``, fixed leaf order), the encode
    g̃_w = Σ_s coeff[w,s]·g_s is ONE single-pass ``coded_reduce`` Pallas call
    (``interpret=True`` off-TPU — auto-detected when ``interpret`` is None),
    and the master decode g = Σ_w a_w·g̃_w is ONE psum over the flat (D,)
    buffer instead of a per-leaf tree walk.  Callers unravel the result once
    with the params structure's ``ravel_pytree`` inverse.

    ``wire_kernel`` (``compress`` only) switches the quantize stage to the
    fused Pallas wire kernels (DESIGN.md §12): encode+quantize+error-feedback
    in ONE kernel — the fp32 wire tensor never materializes in HBM — and the
    decode consumes the int8 wire directly: ``all_gather`` of the (D,) int8
    payloads (4× fewer collective bytes than an fp32 psum) plus the gathered
    per-worker ``a_w·scale_w`` weights, reduced locally by the tiled int8
    kernel.  Replicated-decode semantics are identical to the psum up to
    f32 reduction order.  None → :func:`repro.kernels.autotune.
    wire_kernel_default` (True only where the fused kernel measured faster).

    Manual only over ``coding_axes`` — the 'model' axis stays auto so TP
    sharding inside loss_fn is still handled by GSPMD.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if wire_kernel is None:
        from repro.kernels.autotune import wire_kernel_default

        wire_kernel = compress and wire_kernel_default()

    def worker_fn(params, slot_batch, coeff, a, err):
        # block shapes: slot_batch (1, n_max, mb, ...), coeff (1, n_max),
        # a (1,), err (1, D) or (1, 1)
        sb = jax.tree.map(lambda x: x[0], slot_batch)
        cw = coeff[0]  # (n_max,)

        def slot_grad(carry, slot):
            g = jax.grad(loss_fn)(params, slot)
            return carry, ravel_pytree(g)[0].astype(jnp.float32)

        _, gstack = jax.lax.scan(slot_grad, None, sb)  # (n_max, D)
        if compress and wire_kernel:
            # fused wire path: one kernel encodes straight to the int8 wire
            q, scale, new_err = coded_encode_int8_pallas(
                gstack, cw, err[0], interpret=interpret
            )
            new_err = new_err[None]
            q_all = jax.lax.all_gather(q, coding_axes, tiled=False)  # (W, D) i8
            ws_all = jax.lax.all_gather(scale * a[0], coding_axes)  # (W,)
            decoded = coded_decode_int8_pallas(q_all, ws_all, interpret=interpret)
            return decoded, new_err
        coded = coded_reduce_pallas(gstack, cw, interpret=interpret)  # (D,)
        if compress:
            # wire-format emulation: the flat g̃_w is what travels, so the
            # int8 quantization + error feedback applies to it wholesale
            coded = coded + err[0]
            deq = _dequantize(*_quantize_int8(coded))
            new_err = (coded - deq)[None]
            coded = deq
        else:
            new_err = err
        decoded = jax.lax.psum(coded * a[0], coding_axes)
        return decoded, new_err

    dp = jax.sharding.PartitionSpec(coding_axes)
    rep = jax.sharding.PartitionSpec()
    return _shard_map(
        worker_fn, mesh, in_specs=(rep, dp, dp, dp, dp), out_specs=(rep, dp),
        manual_axes=coding_axes,
    )
