"""Architecture registry: ``get_config(arch_id)`` / ``ARCHS``."""

from repro.configs.base import SHAPES, CodingConfig, ModelConfig, ShapeConfig, TrainConfig, cell_runnable

_ARCH_MODULES = {
    "mamba2-370m": "mamba2_370m",
    "chatglm3-6b": "chatglm3_6b",
    "smollm-360m": "smollm_360m",
    "qwen2.5-14b": "qwen2_5_14b",
    "llama3.2-1b": "llama3_2_1b",
    "internvl2-2b": "internvl2_2b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "mixtral-8x7b": "mixtral_8x7b",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "hubert-xlarge": "hubert_xlarge",
}

ARCHS = tuple(_ARCH_MODULES)


def get_config(arch: str) -> ModelConfig:
    import importlib

    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_ARCH_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch]}")
    return mod.CONFIG


def runnable_cells() -> list[tuple[str, str]]:
    """All (arch, shape) dry-run cells that are runnable per DESIGN.md §5."""
    cells = []
    for arch in ARCHS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            ok, _ = cell_runnable(cfg, shape)
            if ok:
                cells.append((arch, shape.name))
    return cells


__all__ = [
    "ARCHS",
    "SHAPES",
    "CodingConfig",
    "ModelConfig",
    "ShapeConfig",
    "TrainConfig",
    "cell_runnable",
    "get_config",
    "runnable_cells",
]
