"""The paper's own workload analog: small conv-net image classifier.

The paper trains AlexNet on Cifar10 and ResNet34 on ImageNet (§VI).  For the
convergence benchmark (Fig. 4 analog) we use a CPU-feasible conv net on
synthetic 32x32 images — same experimental role (a real gradient-descent
workload under the coding schemes), laptop-scale cost.  Lives outside the
transformer zoo: see benchmarks/fig4_convergence.py for the model definition.
"""

import dataclasses


@dataclasses.dataclass(frozen=True)
class PaperCNNConfig:
    name: str = "paper-cnn"
    img_size: int = 32
    channels: int = 3
    n_classes: int = 10
    widths: tuple[int, ...] = (32, 64)
    hidden: int = 128


CONFIG = PaperCNNConfig()
