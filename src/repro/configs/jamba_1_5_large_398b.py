"""jamba-1.5-large-398b [hybrid] — Mamba+attention 7:1 interleave, MoE 16e top-2.
[arXiv:2403.19887; hf]  72L d_model=8192 64H kv=8 d_ff=24576 vocab=65536.

Period-8 layer pattern: one attention layer per 8 (offset 4, matching the
published interleave), mamba elsewhere; MoE replaces the dense MLP every
2nd layer.  DESIGN.md note: Jamba ships mamba-1 mixers; we substitute
mamba2/SSD blocks (d_inner=2*d, head_dim 64 -> 256 heads, N=128) so one SSM
kernel serves the whole zoo — param count stays within ~2% of 398B."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24576,
    vocab=65536,
    n_experts=16,
    top_k=2,
    expert_d_ff=24576,
    moe_every=2,
    attn_period=8,
    attn_offset=4,
    ssm_d_inner=16384,
    ssm_heads=256,
    ssm_state=128,
    ssm_groups=1,
    ssm_chunk=256,
)
