"""chatglm3-6b [dense] — RoPE 2d (half-dim rotary), GQA kv=2, QKV bias.
[arXiv:2406.12793; hf]  28L d_model=4096 32H kv=2 d_ff=13696 vocab=65024."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b",
    family="dense",
    n_layers=28,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    d_ff=13696,
    vocab=65024,
    rotary_fraction=0.5,  # chatglm rotates only half of each head dim ("2d" RoPE)
    qkv_bias=True,
)
