"""Config system: architecture + shape + run configs (plain dataclasses)."""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """One architecture.  Every assigned arch is an instance of this."""

    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int  # 0 for attention-free archs
    n_kv_heads: int
    d_ff: int  # dense MLP width (per-expert width lives in expert_d_ff)
    vocab: int
    # attention
    head_dim: int = 0  # 0 -> d_model // n_heads
    rope_theta: float = 10000.0
    rotary_fraction: float = 1.0  # chatglm "2d" RoPE rotates half the dims
    qkv_bias: bool = False
    window: int | None = None  # sliding-window attention (mixtral)
    causal: bool = True
    encoder_only: bool = False  # hubert: no decode step exists
    # MoE
    n_experts: int = 0
    top_k: int = 0
    expert_d_ff: int = 0
    moe_every: int = 1  # MoE replaces the dense MLP every N layers
    aux_coef: float = 0.01
    capacity_factor: float = 1.25
    # "dense": GShard one-hot dispatch (GSPMD-friendly, the distributed
    # default); "sort": argsort/scatter dispatch (lean single-device form)
    moe_dispatch: str = "dense"
    # SSM (mamba2 / SSD)
    ssm_d_inner: int = 0
    ssm_heads: int = 0
    ssm_state: int = 0
    ssm_groups: int = 1
    ssm_chunk: int = 256
    conv_kernel: int = 4
    # hybrid (jamba): attention layer every `attn_period` layers (else mamba)
    attn_period: int = 0  # 0 -> pure per-family default
    attn_offset: int = 0
    # frontend stubs
    frontend: str | None = None  # "vision" | "audio"
    n_patches: int = 256  # vision stub: patch embeddings prepended
    # misc
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    act: str = "silu"
    dtype: str = "bfloat16"
    remat: str = "full"  # "none" | "full" — activation checkpointing per block

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def rotary_dim(self) -> int:
        hd = self.resolved_head_dim
        r = int(hd * self.rotary_fraction)
        return r - (r % 2)

    @property
    def supports_decode(self) -> bool:
        return not self.encoder_only

    @property
    def supports_long_context(self) -> bool:
        """long_500k needs sub-quadratic attention state: SSM/hybrid, or SWA."""
        return self.family in ("ssm", "hybrid") or self.window is not None

    def reduced(self) -> "ModelConfig":
        """Smoke-test scale: same family/topology, tiny dims."""
        def shrink(v, lo, cap):
            return max(lo, min(v, cap))

        return dataclasses.replace(
            self,
            n_layers=shrink(self.n_layers, 2, 4 if self.attn_period == 0 else 2 * max(self.attn_period, self.moe_every)),
            d_model=128,
            n_heads=4 if self.n_heads else 0,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_heads else 0,
            head_dim=32 if self.n_heads else 0,
            d_ff=256 if self.d_ff else 0,
            vocab=512,
            n_experts=min(self.n_experts, 4),
            top_k=min(self.top_k, 2),
            expert_d_ff=128 if self.expert_d_ff else 0,
            # no token dropping at toy scale so prefill/decode tests are exact
            capacity_factor=8.0 if self.n_experts else self.capacity_factor,
            ssm_d_inner=256 if self.ssm_d_inner else 0,
            ssm_heads=4 if self.ssm_heads else 0,
            ssm_state=16 if self.ssm_state else 0,
            ssm_chunk=8,
            window=min(self.window, 16) if self.window else None,
            n_patches=8,
            dtype="float32",
            remat="none",
        )


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def cell_runnable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Whether (arch × shape) is a runnable dry-run cell; reason if skipped.
    Skip rules are recorded in DESIGN.md §Arch-applicability."""
    if shape.kind == "decode" and not cfg.supports_decode:
        return False, "encoder-only arch has no decode step"
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, "pure full-attention arch; 500k KV cache is not sub-quadratic"
    return True, ""


@dataclasses.dataclass(frozen=True)
class CodingConfig:
    """Gradient-coding runtime config (the paper's knobs)."""

    scheme: str = "heter_aware"  # heter_aware | group_based | cyclic | naive | fractional_repetition
    s: int = 1  # designed straggler tolerance
    partitions_per_worker: int = 2  # k = m * this (granularity of allocation)
    coding_axes: tuple[str, ...] = ("data",)  # mesh axes that form coded workers
    rebalance_every: int = 50  # steps between c_i re-estimation checks
    deadline_factor: float = 3.0  # straggler if step_time > factor * median
    compress: bool = False  # int8 wire compression (faithful path)
    # fused Pallas wire kernels for the compress path: None = decide on the
    # measuring host (on only where the fused encode beat the unfused
    # composition — repro.kernels.autotune.wire_kernel_default)
    wire_kernel: bool | None = None


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    lr: float = 3e-4
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 1000
    fsdp: bool = False  # ZeRO-style sharding of params/optimizer over 'data'
    seed: int = 0
