"""internvl2-2b [vlm] — InternViT + InternLM2 backbone.
[arXiv:2404.16821; hf]  24L d_model=2048 16H kv=8 d_ff=8192 vocab=92553.

Per spec, the modality frontend is a STUB: ``input_specs()`` provides
precomputed patch embeddings (B, n_patches, d_model) that are prepended to
the token embeddings; the InternViT tower itself is out of scope."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b",
    family="vlm",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_ff=8192,
    vocab=92553,
    frontend="vision",
    n_patches=256,
)
