"""mamba2-370m [ssm] — SSD (state-space duality), attention-free.
[arXiv:2405.21060; unverified]  48L d_model=1024 d_ff=0 vocab=50280 ssm_state=128.
d_inner = 2*d_model = 2048, head_dim 64 -> 32 SSD heads, 1 group."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    n_layers=48,
    d_model=1024,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab=50280,
    ssm_d_inner=2048,
    ssm_heads=32,
    ssm_state=128,
    ssm_groups=1,
    ssm_chunk=256,
    conv_kernel=4,
    tie_embeddings=True,
)
