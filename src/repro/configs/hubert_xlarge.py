"""hubert-xlarge [audio] — encoder-only, wav2vec2-style backbone.
[arXiv:2106.07447; unverified]  48L d_model=1280 16H kv=16 d_ff=5120 vocab=504.

Per spec, the conv waveform frontend is a STUB: ``input_specs()`` provides
precomputed frame embeddings (B, S, d_model).  Encoder-only: bidirectional
attention, frame-level classification head over 504 cluster targets, and no
decode step (decode_32k / long_500k cells are skipped — DESIGN.md §5)."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="audio",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    d_ff=5120,
    vocab=504,
    causal=False,
    encoder_only=True,
    frontend="audio",
    act="gelu",
)
