"""mixtral-8x7b [moe] — 8 experts top-2, sliding-window attention.
[arXiv:2401.04088; hf]  32L d_model=4096 32H kv=8 d_ff=14336 vocab=32000.

SWA window 4096 bounds the decode KV cache, which is why this arch runs the
long_500k cell (ring-buffer cache of 4096 entries, O(1) in stream length)."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=0,
    vocab=32000,
    n_experts=8,
    top_k=2,
    expert_d_ff=14336,
    moe_every=1,
    window=4096,
)
