"""Batched serving example: prefill a batch of requests, decode greedily.

Exercises the same prefill/decode_step code paths the decode_32k/long_500k
dry-run cells lower (KV caches for attention archs, O(1) SSM state for
mamba2 — swap --arch to compare).

  PYTHONPATH=src python examples/serve_lm.py [arch]
"""

import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.lm import build_model
from repro.train.serve import LMServer

arch = sys.argv[1] if len(sys.argv) > 1 else "mamba2-370m"
cfg = get_config(arch).reduced()
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
server = LMServer(model)

rng = np.random.default_rng(0)
B, S, new = 4, 48, 16
requests = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)}
if cfg.frontend == "vision":
    requests["patches"] = jnp.asarray(rng.normal(size=(B, cfg.n_patches, cfg.d_model)) * 0.02,
                                      jnp.float32)

t0 = time.time()
out = server.generate(params, requests, max_new_tokens=new, cache_len=S + new + 8)
dt = time.time() - t0
print(f"arch={cfg.name} batch={B} prefill={S} decoded={new} tokens "
      f"in {dt:.2f}s ({B * new / dt:.1f} tok/s on CPU)")
print("first request tokens:", out[0].tolist())
