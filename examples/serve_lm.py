"""Coded-serving example: an arrival trace through the ServingEngine.

A Poisson stream of requests flows through the full DESIGN.md §9 lifecycle:
queue → admission → coded prefill across a heterogeneous replica pool (the
SLO policy answers from the first decodable replica subset; 30% of replicas
straggle) → continuous-batched decode (requests join/leave the running batch
mid-flight) → per-request completions with TTFT/latency records.

Prints the per-request table plus the p50/p99 summary, including the
wait-for-all counterfactual the coded prefill is beating.

  PYTHONPATH=src python examples/serve_lm.py [arch]
"""

import sys

import jax
import numpy as np

from repro.approx.deadline import SLOPolicy
from repro.configs import get_config
from repro.core.straggler import FixedDelayStragglers
from repro.models.lm import build_model
from repro.serve import ReplicaPool, Request, ServingEngine
from repro.train.serve import LMServer

arch = sys.argv[1] if len(sys.argv) > 1 else "mamba2-370m"
cfg = get_config(arch).reduced()
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
server = LMServer(model)

# heterogeneous replica pool: m=8, speeds 1-4x, 2 stragglers (25%) per request
rng = np.random.default_rng(0)
m, s = 8, 2
pool = ReplicaPool(
    rng.uniform(1.0, 4.0, m), s=s, k=2 * m,
    straggler_model=FixedDelayStragglers(s=s, delay=5.0),
    policy=SLOPolicy.for_slo(ttft_slo_s=np.inf),  # exact-first: earliest decodable subset
    seed=0,
)

engine = ServingEngine(
    server, params, n_slots=4, cache_len=48, replicas=pool, decode_dt=0.01
)

# Poisson arrivals, mixed prompt lengths and budgets
n = 16
arrivals = np.cumsum(rng.exponential(0.3, n))
requests = [
    Request(
        rid=i,
        tokens=rng.integers(0, cfg.vocab, (int(rng.integers(8, 24)),)),
        max_new_tokens=int(rng.integers(6, 14)),
        arrival_t=float(arrivals[i]),
    )
    for i in range(n)
]

completions, metrics = engine.run(requests)

print(f"arch={cfg.name} slots=4 replicas(m={m}, {s} stragglers/request)")
print("rid,prompt,new,ttft_s,latency_s,waitall_ttft_s,replicas_used,exact")
for c in completions:
    r = c.record
    waitall_ttft = r.prefill_all_done_t - r.arrival_t + (r.first_token_t - r.prefill_done_t)
    print(f"{c.rid},{len(requests[c.rid].tokens)},{r.n_tokens},"
          f"{r.ttft:.3f},{r.latency:.3f},{waitall_ttft:.3f},"
          f"{r.replicas_used},{r.prefill_exact}")

s_ = metrics.summary()
ttft_all = [r.prefill_all_done_t - r.arrival_t for r in metrics.records]
print(f"\nrequests={int(s_['n_requests'])} tokens={int(s_['total_tokens'])} "
      f"throughput={s_['tokens_per_s']:.1f} tok/s (virtual clock)")
print(f"TTFT    p50={s_['ttft_p50_s']:.3f}s  p99={s_['ttft_p99_s']:.3f}s "
      f"(wait-for-all p99={np.percentile(ttft_all, 99):.3f}s)")
print(f"latency p50={s_['latency_p50_s']:.3f}s  p99={s_['latency_p99_s']:.3f}s  "
      f"queue_wait_mean={s_['queue_wait_mean_s']:.3f}s")
print(f"prefill exact={s_['prefill_exact_fraction']:.2f} "
      f"replicas_used_mean={s_['replicas_used_mean']:.1f}/{m}")
