"""Elastic restart AND in-place elastic membership.

Phase 1: train on m=4 heterogeneous workers with transient stragglers,
         checkpointing asynchronously.
Phase 2: "the two fast VMs are reclaimed" — restart from the checkpoint on a
         DIFFERENT cluster (m=6, different speeds).  The coding scheme,
         allocation, and decode tables are rebuilt from scratch in
         milliseconds (Alg. 1 is O(mk^2) host-side); model state restores
         exactly; training continues from the same loss.
Phase 3: no restart at all (DESIGN.md §8) — one VM leaves and two join IN
         PLACE: `trainer.remove_workers` / `add_workers` remap the slot
         plan with bounded data movement (retained workers keep their
         partitions wherever the new load shares allow) and re-solve only
         the disturbed Alg. 1 columns; training never stops.

  PYTHONPATH=src python examples/elastic_restart.py
"""

import tempfile

import jax
import numpy as np

from repro.checkpoint import AsyncCheckpointer, latest_step, restore_checkpoint
from repro.configs import CodingConfig, TrainConfig, get_config
from repro.core.straggler import TransientStragglers
from repro.data.pipeline import SyntheticData
from repro.models.lm import build_model
from repro.train.trainer import CodedTrainer, TrainerState

cfg = get_config("smollm-360m").reduced()
model = build_model(cfg)
tc = TrainConfig(lr=1e-3, warmup_steps=3, total_steps=60)
ckdir = tempfile.mkdtemp(prefix="elastic_")

def make(m, speeds, part_mb):
    return CodedTrainer(model, CodingConfig(scheme="heter_aware", s=1), tc,
                        m=m, part_mb=part_mb, straggler_model=TransientStragglers(p=0.1),
                        true_speeds=np.asarray(speeds))

# ---- phase 1: m=4 ----
tr = make(4, [1, 2, 4, 4], part_mb=3)
data = SyntheticData(cfg, k=tr.k, part_mb=3, seq_len=32)
state = tr.init_state(jax.random.PRNGKey(0))
ck = AsyncCheckpointer(ckdir)
for step in range(12):
    state, met = tr.step(state, data.batch(step))
    if (step + 1) % 6 == 0:
        ck.save(step + 1, {"params": state.params, "opt": state.opt},
                meta={"m": 4, "loss": met["loss"]})
ck.wait()
print(f"phase 1 (m=4): step 12 loss {met['loss']:.4f}, checkpoint at {ckdir}")

# ---- phase 2: cluster changed to m=6, different speeds ----
tr2 = make(6, [1, 1, 2, 2, 3, 3], part_mb=2)
data2 = SyntheticData(cfg, k=tr2.k, part_mb=2, seq_len=32)
last = latest_step(ckdir)
tmpl = tr2.init_state(jax.random.PRNGKey(1))
restored, meta = restore_checkpoint(ckdir, last, {"params": tmpl.params, "opt": tmpl.opt})
state2 = TrainerState(params=restored["params"], opt=restored["opt"], step=last)
print(f"restored step {last} (saved on m={meta['m']}, resuming on m=6; "
      f"new allocation n_i = {tr2.scheme.allocation.counts})")
for step in range(last, last + 10):
    state2, met2 = tr2.step(state2, data2.batch(step))
print(f"phase 2 (m=6): step {state2.step} loss {met2['loss']:.4f} "
      f"(continued from {meta['loss']:.4f})")
assert met2["loss"] < meta["loss"] * 1.1, "loss should continue falling after elastic restart"
print("elastic restart OK")

# ---- phase 3: in-place membership change, no restart (DESIGN.md §8) ----
stats = tr2.remove_workers([1])                 # a slow VM is reclaimed
stats2 = tr2.add_workers([4.0, 4.0])            # two fast ones join
print(f"phase 3 (m={tr2.m} in place): leave moved {stats.moved} copies "
      f"(bound {stats.bound}), join moved {stats2.moved} "
      f"(re-solved {stats2.changed_columns}/{tr2.k} B columns)")
for step in range(state2.step, state2.step + 6):
    state2, met3 = tr2.step(state2, data2.batch(step))
assert met3["membership_epoch"] == 2.0
print(f"phase 3: step {state2.step} loss {met3['loss']:.4f} — "
      "in-place elastic membership OK")
