"""Quickstart: the paper's scheme through the registry API in ~40 lines.

Builds a heterogeneity-aware gradient code for a 5-worker cluster (the
paper's Example 1) via ``get_scheme``, shows that any single straggler is
survivable with zero time penalty, and decodes an exact gradient on a toy
model through a ``Codec``.  See DESIGN.md for the API tour.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    Codec,
    ClusterSim,
    FixedDelayStragglers,
    get_scheme,
    scheme_names,
    theoretical_optimal_time,
)
from repro.core.aggregator import fused_coded_value_and_grad

# --- the paper's Example 1: 5 workers with speeds 1:2:3:4:4, one straggler ---
c = np.array([1.0, 2.0, 3.0, 4.0, 4.0])
code = get_scheme("heter_aware", m=5, k=7, s=1, c=c, rng=0)  # any of scheme_names()
print("registered schemes:", ", ".join(scheme_names()))
print("allocation n_i:", code.allocation.counts)  # (1, 2, 3, 4, 4) — Eq. 5
print("C·B == 1:", np.allclose(code.scheme.C @ code.B, 1.0))

# --- any worker may die; iteration time stays at the Thm.5 optimum ---
sim = ClusterSim(code, c)
res = sim.run(FixedDelayStragglers(s=1, delay=np.inf), n_iters=100, rng=0)
print(f"iteration time with a fault every step: {res.mean_T:.4f}s "
      f"(optimum {theoretical_optimal_time(7, 1, c):.4f}s, failures={res.failures})")

# --- exact gradient recovery on real math ---
def loss_fn(params, batch):
    return jnp.mean((batch["x"] @ params["w"] - batch["y"]) ** 2)

rng = np.random.default_rng(0)
params = {"w": jnp.asarray(rng.normal(size=(8,)), jnp.float32)}
batch = {"x": jnp.asarray(rng.normal(size=(7, 4, 8)), jnp.float32),
         "y": jnp.asarray(rng.normal(size=(7, 4)), jnp.float32)}

codec = Codec(code)  # device-feedable slot plan, shape-stable under rebalance
worker_3_died = [0, 1, 2, 4]
weights = codec.slot_weights(codec.decode_vector(worker_3_died))
loss, grads = fused_coded_value_and_grad(loss_fn)(
    params, codec.pack(batch), jnp.asarray(weights))

truth = jax.tree.map(jnp.zeros_like, params)
for j in range(7):
    g = jax.grad(loss_fn)(params, jax.tree.map(lambda x: x[j], batch))
    truth = jax.tree.map(lambda a, b: a + b / 7, truth, g)
print("decoded grad == true grad (worker 3 dead):",
      bool(jnp.allclose(grads["w"], truth["w"], atol=1e-5)))
