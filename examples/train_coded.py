"""End-to-end driver: coded data-parallel training of a ~100M-class LM with
per-step faults, elastic throughput re-estimation, and async checkpoints.

Default invocation trains a width/depth-reduced llama config for a few
hundred steps on CPU (env SMOKE=1 shrinks further for CI):

  PYTHONPATH=src python examples/train_coded.py

This is a thin veneer over the production launcher — the same run via CLI:

  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --reduced \\
      --steps 300 --scheme heter_aware --s 1 --m 6 --straggler fault \\
      --speeds 1,1,2,2,4,4 --ckpt-dir /tmp/coded_ckpt
"""

import os

from repro.launch.train import main

SMOKE = os.environ.get("SMOKE", "0") == "1"

if __name__ == "__main__":
    main([
        "--arch", "llama3.2-1b",
        "--reduced",
        "--steps", "40" if SMOKE else "300",
        "--scheme", "heter_aware",
        "--s", "1",
        "--m", "6",
        "--part-mb", "2",
        "--seq-len", "64" if SMOKE else "128",
        "--straggler", "fault",
        "--speeds", "1,1,2,2,4,4",
        "--ckpt-dir", "/tmp/coded_ckpt",
        "--ckpt-every", "20",
    ])
