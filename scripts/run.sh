#!/usr/bin/env bash
# Hardened launcher for real (TPU-)host runs: sets the environment every
# long training job wants before python even starts, then execs the given
# command.  Usage:
#
#   ./scripts/run.sh python -m repro.launch.train --arch smollm-360m --reduced
#   CPU_DEVICES=8 ./scripts/run.sh python tests/spmd_driver.py engine_spmd
#
# Everything is overridable: any variable already exported by the caller
# wins.  The launcher only fills gaps, so it is safe as the default entry
# point in cron/CI and on interactive TPU VMs alike.
set -euo pipefail
cd "$(dirname "$0")/.."

# --- allocator: tcmalloc beats glibc malloc for the host-side pack path's
# large short-lived buffers; preload only if the host actually has it
TCMALLOC=/usr/lib/x86_64-linux-gnu/libtcmalloc.so.4
if [[ -z "${LD_PRELOAD:-}" && -e "$TCMALLOC" ]]; then
  export LD_PRELOAD="$TCMALLOC"
fi
# large allocs are normal here (gradient stacks, coded batches): silence
# tcmalloc's per-allocation report spam above this many bytes
export TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD="${TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD:-60000000000}"

# --- logging: TF's C++ backend (libtpu, tsl) floods stderr at INFO;
# 4 = errors only.  JAX's own logging is unaffected.
export TF_CPP_MIN_LOG_LEVEL="${TF_CPP_MIN_LOG_LEVEL:-4}"

# --- XLA flags (appended to whatever the caller set):
#   --xla_step_marker_location=1: mark the outer while loop as the step
#     boundary so TPU profiles cut traces at training-step granularity.
#     TPU-only flag — CPU/GPU jaxlib aborts on unknown XLA flags, so only
#     add it when the host actually looks like a TPU VM.
#   CPU_DEVICES=n: fake host devices for mesh tests on machines without
#     accelerators (tests/spmd_driver.py sets its own; this is for ad-hoc)
XF="${XLA_FLAGS:-}"
if [[ -e /dev/accel0 || -n "${TPU_NAME:-}" || -n "${TPU_WORKER_ID:-}" ]]; then
  case "$XF" in *xla_step_marker_location*) ;; *) XF="$XF --xla_step_marker_location=1";; esac
fi
if [[ -n "${CPU_DEVICES:-}" ]]; then
  case "$XF" in *xla_force_host_platform_device_count*) ;;
    *) XF="$XF --xla_force_host_platform_device_count=${CPU_DEVICES}";; esac
fi
export XLA_FLAGS="${XF# }"

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
exec "$@"
