#!/usr/bin/env bash
# Tier-1 test gate: run from anywhere, extra pytest args pass through.
#   ./scripts/test.sh                    # full suite
#   ./scripts/test.sh tests/test_coding.py -k decode
set -euo pipefail
cd "$(dirname "$0")/.."
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" exec python -m pytest -x -q "$@"
