#!/usr/bin/env bash
# Tier-1 test gate: run from anywhere, extra pytest args pass through.
#   ./scripts/test.sh                    # full suite
#   ./scripts/test.sh tests/test_coding.py -k decode
#   RUN_TIER2=1 ./scripts/test.sh        # + tier-2: benchmark smoke (fig2-6)
set -euo pipefail
cd "$(dirname "$0")/.."
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m pytest -x -q "$@"
echo "== tier-1: spmd elastic rebuild (tests/spmd_driver.py engine_spmd_elastic, 8 fake devices) =="
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python tests/spmd_driver.py engine_spmd_elastic
if [[ "${RUN_TIER2:-0}" == "1" ]]; then
  echo "== tier-2: benchmark smoke (BENCH_FAST=1 benchmarks/run.py) =="
  make bench-smoke
  echo "== tier-2: large-m scaling gate (BENCH_FAST=1 benchmarks/scaling.py) =="
  make bench-scaling
  echo "== tier-2: membership churn soak (50 transitions, m up to 64) =="
  make churn-soak
  echo "== tier-2: coded-serving gate (BENCH_FAST=1 benchmarks/serving.py) =="
  make bench-serving
  echo "== tier-2: observability overhead gate (BENCH_FAST=1 benchmarks/obs_overhead.py) =="
  make bench-obs
  echo "== tier-2: chaos soak (mixed crash/hang/flaky/corrupt runs at m=10) =="
  make chaos-soak
  echo "== tier-2: resilience gate (BENCH_FAST=1 benchmarks/resilience.py) =="
  make bench-resilience
  echo "== tier-2: kernel roofline gate (BENCH_FAST=1 benchmarks/kernels_bench.py) =="
  make bench-kernels
fi
